//! Warm-start determinism and accounting: `coordinator::state` must make
//! a run a *resumable value*.
//!
//! Pinned here:
//!
//! - the session snapshot/restore round-trip is bit-exact (predictions
//!   AND continued training);
//! - a resumed run continues a never-paused run's trajectory bit-exactly
//!   (PRNG streams, acquisition picks, ε_T profiles), and its ledger
//!   total is the cold run's minus exactly the duplicated pre-snapshot
//!   training spend (labels cost the same to the bit — the re-buy lands
//!   in the same integer price bucket);
//! - warm-started arch selection is `--ingest-*`- and `--jobs`-invariant:
//!   bit-identical `RunReport`s, with the two documented config-shaped
//!   order-log segments (the warm re-buy prefix in the reserved
//!   [`WARM_ORDER_BASE`] id space, and the residual suffix) collapsed to
//!   their invariant label totals — every order id *between* them must
//!   match verbatim, which is what the reserved id space buys. (All runs
//!   here use the paper's perfect annotators; with injected label errors
//!   the re-buy's error realization follows the order split by design —
//!   see `coordinator::state`'s documented carve-out;)
//! - a warm-started cell reports strictly lower `training` spend than a
//!   `--no-warm-start` run of the same cell.
//!
//! Artifact-gated like the other integration suites: skips when
//! `artifacts/` is absent (run `make artifacts` first).

use std::sync::Arc;

use mcal::annotation::{AnnotationService, Ledger, SimService, SimServiceConfig};
use mcal::coordinator::{
    run_with_arch_selection, ArchSelectConfig, LabelingDriver, LabelingEnv, ProbeResult,
    RunParams, RunReport,
};
use mcal::model::{ArchKind, TrainSchedule};
use mcal::runtime::{EnginePool, ModelSession};

mod common;
use common::{ingest_configs, residual_cut, setup, smoke_dataset, Fixture};

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn bits64(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn session_state_roundtrip_is_bit_exact() {
    let Some(f) = setup() else { return };
    let (ds, preset) = smoke_dataset("fashion-syn", 11);
    let model = ArchKind::Res18.model_set(preset.classes_tag);
    let sched = TrainSchedule::default();

    let mut a = ModelSession::open(&f.engine, &f.manifest, &model, 11).unwrap();
    let idx: Vec<usize> = (0..256).collect();
    let labels: Vec<u32> = idx.iter().map(|&i| ds.groundtruth(i)).collect();
    a.train_epochs(&ds, &idx, &labels, 2, ArchKind::Res18.base_lr(), &sched).unwrap();

    let state = a.state_host().unwrap();
    let rng = a.rng_snapshot();
    let probe_idx: Vec<usize> = (300..556).collect();
    let scores_a = a.predict(&ds, &probe_idx).unwrap();

    // A fresh session under a *different* init seed: restore must
    // overwrite its state and rng completely.
    let mut b = ModelSession::open(&f.engine, &f.manifest, &model, 999).unwrap();
    b.restore(&state, rng).unwrap();
    assert_eq!(
        bits32(&b.state_host().unwrap()),
        bits32(&state),
        "host → device → host state round-trip must be bit-exact"
    );
    let scores_b = b.predict(&ds, &probe_idx).unwrap();
    assert_eq!(bits32(&scores_a.margin), bits32(&scores_b.margin));
    assert_eq!(scores_a.pred, scores_b.pred);

    // Training *continues* identically too: same rng cursor, same data,
    // same resulting weights — the restored session is the session.
    let more: Vec<usize> = (600..856).collect();
    let more_labels: Vec<u32> = more.iter().map(|&i| ds.groundtruth(i)).collect();
    a.train_epochs(&ds, &more, &more_labels, 1, 0.01, &sched).unwrap();
    b.train_epochs(&ds, &more, &more_labels, 1, 0.01, &sched).unwrap();
    assert_eq!(
        bits32(&a.state_host().unwrap()),
        bits32(&b.state_host().unwrap()),
        "post-restore training must continue the captured stream"
    );

    // Truncated snapshots are a clean error, not a shape panic.
    let rng_b = b.rng_snapshot();
    assert!(b.restore(&state[..state.len() - 1], rng_b).is_err());
}

/// Drive one acquire → retrain → measure round and return the measured
/// profile's bits (the cadence `LabelingDriver::drive` runs).
fn round(env: &mut LabelingEnv<'_>, delta: usize) -> Vec<u64> {
    assert!(env.acquire(delta).unwrap() > 0);
    env.retrain().unwrap();
    bits64(&env.measure().unwrap())
}

#[test]
fn resumed_run_matches_never_paused_run_and_saves_the_training_dollars() {
    let Some(f) = setup() else { return };
    let (ds, preset) = smoke_dataset("fashion-syn", 29);
    let params = RunParams { seed: 29, ..Default::default() };
    let delta = ds.len() / 25;

    // Never-paused reference run: setup + 3 rounds, snapshot point, then
    // 2 more rounds.
    let ledger1 = Arc::new(Ledger::new());
    let svc1 = SimService::new(SimServiceConfig::default().with_seed(29), ledger1.clone());
    let mut cold = LabelingEnv::new(
        &f.engine,
        &f.manifest,
        &ds,
        &svc1 as &dyn AnnotationService,
        ledger1.clone(),
        ArchKind::Res18,
        preset.classes_tag,
        params.clone(),
        mcal::cost::theta_grid(),
    )
    .unwrap();
    cold.measure().unwrap();
    for _ in 0..3 {
        round(&mut cold, delta);
    }
    let snap = cold.snapshot(3).unwrap();
    let pre_training = snap.training_spend;
    assert!(pre_training > 0.0);

    let cold_tail: Vec<Vec<u64>> = (0..2).map(|_| round(&mut cold, delta)).collect();

    // Resume the snapshot on a fresh ledger and a *chunked, laggy*
    // service — the re-buy streams, the trajectory must not move.
    let ledger2 = Arc::new(Ledger::new());
    let svc2 = SimService::new(
        SimServiceConfig::default()
            .with_seed(29)
            .with_chunk(7)
            .with_workers(3)
            .with_latency(std::time::Duration::from_micros(50)),
        ledger2.clone(),
    );
    let mut warm = LabelingEnv::resume(
        &f.engine,
        &f.manifest,
        &ds,
        &svc2 as &dyn AnnotationService,
        ledger2.clone(),
        preset.classes_tag,
        params,
        snap,
    )
    .unwrap();
    let ws = warm.warm_start.clone().expect("resumed env carries provenance");
    assert_eq!(ws.rounds_skipped, 3);
    assert_eq!(ws.labels_rebought, warm.test_idx.len() + warm.b_idx.len());
    assert_eq!(ws.training_saved.to_bits(), pre_training.to_bits());

    let warm_tail: Vec<Vec<u64>> = (0..2).map(|_| round(&mut warm, delta)).collect();

    // Bit-exact continuation: profiles, acquisition picks, labels, fit
    // history, and the session weights themselves.
    assert_eq!(cold_tail, warm_tail, "resumed ε_T trajectory drifted");
    assert_eq!(cold.b_idx, warm.b_idx, "resumed acquisition picks drifted");
    assert_eq!(cold.b_labels, warm.b_labels);
    assert_eq!(cold.test_labels, warm.test_labels);
    assert_eq!(
        bits64(&cold.cost_obs.iter().map(|&(_, d)| d).collect::<Vec<_>>()),
        bits64(&warm.cost_obs.iter().map(|&(_, d)| d).collect::<Vec<_>>()),
    );
    assert_eq!(
        bits32(&cold.session.state_host().unwrap()),
        bits32(&warm.session.state_host().unwrap()),
        "resumed model weights drifted from the never-paused run"
    );

    // The accounting identity the warm start exists for: same labels to
    // the bit (the re-buy lands in the same integer price bucket), and
    // the total differs by exactly the duplicated pre-snapshot training.
    let c1 = ledger1.snapshot();
    let c2 = ledger2.snapshot();
    assert_eq!(c1.human_labeling.to_bits(), c2.human_labeling.to_bits());
    assert_eq!(c1.labels_purchased, c2.labels_purchased);
    assert!(
        (c1.training - c2.training - pre_training).abs() < 1e-9,
        "warm training ({}) must be cold training ({}) minus the duplicated \
         pre-snapshot spend ({pre_training})",
        c2.training,
        c1.training
    );
    assert!(
        (ledger1.total() - ledger2.total() - pre_training).abs() < 1e-9,
        "warm ledger total must equal cold minus the duplicated training spend"
    );
}

/// Deterministic key over a warm-started report: everything bit-compared,
/// with the two documented config-shaped order-log segments collapsed —
/// the warm re-buy prefix (reserved-id orders; its *count* follows
/// `--ingest-chunk`) to its label total, and the residual suffix
/// likewise. Every order between them is compared verbatim, ids included:
/// the reserved warm id space is what keeps those ids chunk-invariant.
fn warm_key(r: &RunReport) -> String {
    use std::fmt::Write as _;
    let warm_n = r.orders.iter().filter(|o| o.id.is_warm()).count();
    assert!(
        r.orders[..warm_n].iter().all(|o| o.id.is_warm()),
        "warm re-buy orders must lead the log"
    );
    let ws = r.warm_start.as_ref().expect("warm run must carry provenance");
    let warm_labels: u64 = r.orders[..warm_n].iter().map(|o| o.labels).sum();
    assert_eq!(warm_labels as usize, ws.labels_rebought);

    let mut s = String::new();
    let _ = writeln!(
        s,
        "seed={} arch={} b={} s={} residual={} err_bits={}/{}/{} cost_bits={} \
         human_only_bits={} stop={:?} warm_rounds={} warm_labels={} warm_saved_bits={}",
        r.seed,
        r.arch,
        r.b_size,
        r.s_size,
        r.residual_human,
        r.overall_error.to_bits(),
        r.machine_error.to_bits(),
        r.residual_label_error.to_bits(),
        r.cost.total().to_bits(),
        r.human_only_cost.to_bits(),
        r.stop_reason,
        ws.rounds_skipped,
        ws.labels_rebought,
        ws.training_saved.to_bits(),
    );
    for it in &r.iterations {
        let profile: Vec<u64> = it.eps_profile.iter().map(|e| e.to_bits()).collect();
        let _ = writeln!(
            s,
            "iter={} b={} delta={} ledger_bits={} c_star_bits={:?} stable={} profile={profile:?}",
            it.iter,
            it.b_size,
            it.delta,
            it.ledger_total.to_bits(),
            it.c_star.map(f64::to_bits),
            it.stable,
        );
    }
    let cut = residual_cut(r);
    assert!(cut >= warm_n);
    for o in &r.orders[warm_n..cut] {
        let _ = writeln!(
            s,
            "order={} labels={} dollars_bits={}",
            o.id,
            o.labels,
            o.dollars.to_bits()
        );
    }
    let _ = writeln!(s, "residual labels={}", r.residual_human);
    s
}

fn arch_run(
    f: &Fixture,
    cfg: SimServiceConfig,
    pool: Option<&EnginePool>,
    warm_start: bool,
    seed: u64,
) -> (RunReport, Vec<ProbeResult>) {
    let (ds, preset) = smoke_dataset("cifar10-syn", seed);
    let ledger = Arc::new(Ledger::new());
    let svc = SimService::new(cfg, ledger.clone());
    let params = RunParams { seed, ..Default::default() };
    let driver = LabelingDriver::new(&f.engine, &f.manifest).with_pool(pool);
    run_with_arch_selection(
        &driver,
        &ds,
        &svc,
        ledger,
        &preset.candidate_archs,
        preset.classes_tag,
        params,
        ArchSelectConfig { probe_iters: 5, warm_start },
    )
    .unwrap()
}

#[test]
fn warm_arch_selection_is_ingest_and_jobs_invariant() {
    let Some(f) = setup() else { return };
    let configs = ingest_configs(33);
    let mut keys = Vec::new();
    for cfg in &configs {
        let (report, _) = arch_run(&f, cfg.clone(), None, true, 33);
        keys.push(warm_key(&report));
    }
    for (i, k) in keys.iter().enumerate().skip(1) {
        assert_eq!(
            k, &keys[0],
            "warm-started run drifted under ingest config #{i} — the re-buy \
             must be a pure wall-clock knob"
        );
    }
    // And across pool widths, with the laggiest chunked config.
    let pool = EnginePool::new(2).unwrap();
    let (report, _) = arch_run(&f, configs[2].clone(), Some(&pool), true, 33);
    assert_eq!(
        warm_key(&report),
        keys[0],
        "warm-started run drifted under a 3-lane pool"
    );
}

#[test]
fn warm_start_reports_strictly_lower_training_spend_than_cold() {
    let Some(f) = setup() else { return };
    let cfg = ingest_configs(33)[0].clone();
    let (warm, warm_probes) = arch_run(&f, cfg.clone(), None, true, 33);
    let (cold, cold_probes) = arch_run(&f, cfg, None, false, 33);

    // The probe phase is untouched by the warm flag.
    let pk = |ps: &[ProbeResult]| ps.iter().map(ProbeResult::bit_key).collect::<Vec<_>>();
    assert_eq!(pk(&warm_probes), pk(&cold_probes));
    assert_eq!(warm.arch, cold.arch, "warm start must not change the winner");

    // The headline: the winner no longer re-pays its probe. (The margin
    // is seed-specific — warm and cold trajectories legitimately differ —
    // but the structure is not: cold re-trains from init through the
    // whole early ramp the probe already paid for.)
    assert!(
        warm.cost.training < cold.cost.training,
        "warm training ${} must be strictly below cold training ${}",
        warm.cost.training,
        cold.cost.training
    );
    assert!(cold.warm_start.is_none());
    let ws = warm.warm_start.as_ref().unwrap();
    let winner_probe = warm_probes
        .iter()
        .find(|p| p.arch.as_str() == warm.arch)
        .unwrap();
    assert_eq!(ws.training_saved.to_bits(), winner_probe.training_spend.to_bits());
    assert!(ws.labels_rebought > 0 && ws.rounds_skipped > 0);
    // Exploration (losers' probes) is charged identically either way.
    assert_eq!(
        warm.cost.exploration.to_bits(),
        cold.cost.exploration.to_bits()
    );
}
