//! Budget-constrained labeling (§4 "Accommodating a budget constraint"):
//! instead of an error bound, give MCAL a fixed dollar budget and let it
//! minimize labeling error. Demonstrates the error/cost trade at three
//! budget levels.
//!
//! ```bash
//! cargo run --release --offline --example budget_constrained
//! ```

use std::sync::Arc;

use mcal::annotation::{Ledger, Service, SimService, SimServiceConfig};
use mcal::coordinator::{run_budget, LabelingDriver, RunParams};
use mcal::dataset::preset;
use mcal::model::ArchKind;
use mcal::report::Table;
use mcal::runtime::{Engine, Manifest};

fn main() -> mcal::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load("artifacts")?;
    let p = preset("fashion-syn", 7)?;
    let mut ds = p.spec.scaled(0.1).generate()?;
    ds.name = "fashion-syn".into();
    let human_only = ds.len() as f64 * Service::Amazon.price_per_label();
    println!("dataset: {} samples | human-only cost ${human_only:.2}", ds.len());

    let mut t = Table::new(
        "Budget-constrained MCAL (fashion-syn @ 10%, Amazon)",
        &["budget", "spent", "machine_frac", "b_frac", "overall_error", "stop"],
    );
    for frac in [0.25, 0.5, 0.9] {
        let budget = human_only * frac;
        let ledger = Arc::new(Ledger::new());
        let service = SimService::new(
            SimServiceConfig { service: Service::Amazon, ..Default::default() },
            ledger.clone(),
        );
        let report = run_budget(
            &LabelingDriver::new(&engine, &manifest),
            &ds,
            &service,
            ledger.clone(),
            ArchKind::Res18,
            p.classes_tag,
            RunParams { seed: 7, ..Default::default() },
            budget,
        )?;
        t.push_row([
            format!("${budget:.2}"),
            format!("${:.2}", ledger.total()),
            format!("{:.1}%", report.machine_frac() * 100.0),
            format!("{:.1}%", report.b_frac() * 100.0),
            format!("{:.2}%", report.overall_error * 100.0),
            format!("{:?}", report.stop_reason),
        ]);
    }
    println!("\n{}", t.to_markdown());
    println!("Tighter budgets force more machine labeling (and more error);");
    println!("looser budgets buy error down with human labels.");
    Ok(())
}
