//! Labeling-service comparison (§5.3 "Cheaper Labeling Cost"): run MCAL on
//! the same dataset under Amazon ($0.04/label) and Satyam ($0.003/label)
//! pricing and show how the optimizer re-balances human labels vs training
//! spend — with cheap labels MCAL buys *more* training data.
//!
//! ```bash
//! cargo run --release --offline --example service_comparison
//! ```

use std::sync::Arc;

use mcal::annotation::{Ledger, Service, SimService, SimServiceConfig};
use mcal::coordinator::{run_mcal, LabelingDriver, RunParams};
use mcal::dataset::preset;
use mcal::model::ArchKind;
use mcal::report::Table;
use mcal::runtime::{Engine, Manifest};

fn main() -> mcal::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load("artifacts")?;

    let mut t = Table::new(
        "MCAL under two labeling services (cifar10-syn @ 10%, res18)",
        &["service", "$/label", "total", "savings", "B/X", "S/X", "train_cost", "train_share"],
    );
    for svc in [Service::Amazon, Service::Satyam] {
        let p = preset("cifar10-syn", 21)?;
        let mut ds = p.spec.scaled(0.1).generate()?;
        ds.name = "cifar10-syn".into();
        let ledger = Arc::new(Ledger::new());
        let service = SimService::new(
            SimServiceConfig { service: svc, ..Default::default() },
            ledger.clone(),
        );
        let report = run_mcal(
            &LabelingDriver::new(&engine, &manifest),
            &ds,
            &service,
            ledger,
            ArchKind::Res18,
            p.classes_tag,
            RunParams { seed: 21, ..Default::default() },
        )?;
        t.push_row([
            svc.name(),
            format!("{:.3}", svc.price_per_label()),
            format!("${:.2}", report.cost.total()),
            format!("{:.1}%", report.savings() * 100.0),
            format!("{:.1}%", report.b_frac() * 100.0),
            format!("{:.1}%", report.machine_frac() * 100.0),
            format!("${:.2}", report.cost.training),
            format!("{:.1}%", report.cost.training / report.cost.total() * 100.0),
        ]);
    }
    println!("{}", t.to_markdown());
    println!("Note the training share of total cost: with 13x cheaper labels,");
    println!("training dollars matter more, so MCAL's delta adaptation and");
    println!("stopping point shift (paper §5.3, Tbl. 1 Satyam rows).");
    Ok(())
}
