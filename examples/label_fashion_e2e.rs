//! End-to-end validation driver (docs/DESIGN.md §End-to-end): run the complete
//! MCAL pipeline — synthetic Fashion-MNIST workload at full 70k scale,
//! automatic architecture selection across {cnn18, res18, res50}, Amazon
//! pricing — and report the paper's headline metric (total labeling cost
//! vs human-only, Table 1 row 1). Recorded in docs/DESIGN.md §End-to-end.
//!
//! ```bash
//! cargo run --release --offline --example label_fashion_e2e
//! ```

use std::sync::Arc;
use std::time::Instant;

use mcal::annotation::{Ledger, Service, SimService, SimServiceConfig};
use mcal::coordinator::{run_with_arch_selection, ArchSelectConfig, LabelingDriver, RunParams};
use mcal::dataset::preset;
use mcal::report::Table;
use mcal::runtime::{Engine, EnginePool, Manifest};

fn main() -> mcal::Result<()> {
    let t0 = Instant::now();
    let engine = Engine::cpu()?;
    let manifest = Manifest::load("artifacts")?;

    let p = preset("fashion-syn", 42)?;
    let ds = p.spec.generate()?; // full 70,000 samples
    println!(
        "workload: {} ({} samples, {} classes) | candidates: {:?} | service: Amazon ($0.04/label)",
        ds.name,
        ds.len(),
        ds.num_classes,
        p.candidate_archs
    );

    let ledger = Arc::new(Ledger::new());
    let service = SimService::new(
        SimServiceConfig { service: Service::Amazon, ..Default::default() },
        ledger.clone(),
    );

    // Spend every core on the run: probe lanes × intra-run measure shards.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let pool = EnginePool::for_budget(cores, p.candidate_archs.len())?;
    let driver = LabelingDriver::new(&engine, &manifest).with_pool(Some(&pool));

    let (report, probes) = run_with_arch_selection(
        &driver,
        &ds,
        &service,
        ledger,
        &p.candidate_archs,
        p.classes_tag,
        RunParams { seed: 42, ..Default::default() },
        ArchSelectConfig::default(),
    )?;

    println!("\n== architecture probe phase ==");
    for pr in &probes {
        println!(
            "  {}: C*={} stable={} probe-training=${:.2}",
            pr.arch,
            pr.c_star.map(|c| format!("${c:.2}")).unwrap_or_else(|| "-".into()),
            pr.stable,
            pr.training_spend
        );
    }

    println!("\n== final labeling run ==");
    if let Some(ws) = &report.warm_start {
        println!(
            "  warm-started from the winning probe: resumed at round {}, {} labels re-bought, ${:.2} probe training inherited",
            ws.rounds_skipped, ws.labels_rebought, ws.training_saved
        );
    }
    println!("{}", report.summary());
    for it in &report.iterations {
        println!(
            "  iter {:>2}: |B|={:>6} δ={:>5} retrain=${:<7.2} C*={} B_opt={} θ*={} stable={}",
            it.iter,
            it.b_size,
            it.delta,
            it.retrain_dollars,
            it.c_star.map(|c| format!("${c:.0}")).unwrap_or_else(|| "-".into()),
            it.b_opt.map(|b| b.to_string()).unwrap_or_else(|| "-".into()),
            it.theta_star.map(|t| format!("{t:.2}")).unwrap_or_else(|| "-".into()),
            it.stable,
        );
    }

    // Headline metric table (paper Table 1, Fashion/Amazon row).
    let mut t = Table::new(
        "E2E headline — fashion-syn / Amazon (paper: 86% savings, |B|=6.1%, |S|=85%, err 4.0%)",
        &["metric", "paper", "measured"],
    );
    t.push_row([
        "human-only cost".into(),
        "$2800".into(),
        format!("${:.2}", report.human_only_cost),
    ]);
    t.push_row(["MCAL cost".into(), "$400".into(), format!("${:.2}", report.cost.total())]);
    t.push_row(["savings".into(), "86%".into(), format!("{:.1}%", report.savings() * 100.0)]);
    t.push_row(["|B|/|X|".into(), "6.1%".into(), format!("{:.1}%", report.b_frac() * 100.0)]);
    t.push_row([
        "|S|/|X|".into(),
        "85.0%".into(),
        format!("{:.1}%", report.machine_frac() * 100.0),
    ]);
    t.push_row([
        "label error".into(),
        "4.0%".into(),
        format!("{:.2}%", report.overall_error * 100.0),
    ]);
    t.push_row(["DNN selected".into(), "res18".into(), report.arch.clone()]);
    println!("\n{}", t.to_markdown());
    let path = t.write_csv("results", "e2e_fashion")?;
    println!("wrote {} | wall {:.1}s", path.display(), t0.elapsed().as_secs_f64());

    assert!(report.savings() > 0.5, "E2E regression: savings collapsed");
    assert!(report.overall_error < report.epsilon + 0.01, "E2E regression: error bound violated");
    println!("E2E OK");
    Ok(())
}
