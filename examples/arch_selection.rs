//! Architecture selection (§4 "Extending MCAL to selecting the cheapest
//! DNN architecture"): probe cnn18 / res18 / res50 until their C* estimates
//! stabilize, commit to the cheapest, and charge the losers' probe training
//! as exploration tax.
//!
//! ```bash
//! cargo run --release --offline --example arch_selection
//! ```

use std::sync::Arc;

use mcal::annotation::{Ledger, Service, SimService, SimServiceConfig};
use mcal::coordinator::{run_with_arch_selection, ArchSelectConfig, LabelingDriver, RunParams};
use mcal::dataset::preset;
use mcal::report::Table;
use mcal::runtime::{Engine, EnginePool, Manifest};

fn main() -> mcal::Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load("artifacts")?;
    let p = preset("cifar10-syn", 5)?;
    let mut ds = p.spec.scaled(0.1).generate()?;
    ds.name = "cifar10-syn".into();

    let ledger = Arc::new(Ledger::new());
    let service = SimService::new(
        SimServiceConfig { service: Service::Amazon, ..Default::default() },
        ledger.clone(),
    );

    // One pool lane per candidate: the three probes run concurrently, and
    // the results are bit-identical to a serial run (drop `.with_pool` to
    // see for yourself).
    let pool = EnginePool::new(p.candidate_archs.len() - 1)?;
    let driver = LabelingDriver::new(&engine, &manifest).with_pool(Some(&pool));

    let (report, probes) = run_with_arch_selection(
        &driver,
        &ds,
        &service,
        ledger,
        &p.candidate_archs,
        p.classes_tag,
        RunParams { seed: 5, ..Default::default() },
        // Default config: 8 probe rounds, winner warm-started from its
        // probe state (set `warm_start: false` to re-run it from scratch).
        ArchSelectConfig::default(),
    )?;

    let mut t = Table::new(
        "Architecture probe phase (cifar10-syn @ 10%, Amazon)",
        &["arch", "C* estimate", "stable", "B probed", "probe training $"],
    );
    for pr in &probes {
        t.push_row([
            pr.arch.to_string(),
            pr.c_star.map(|c| format!("${c:.2}")).unwrap_or_else(|| "-".into()),
            pr.stable.to_string(),
            pr.b_probed.to_string(),
            format!("{:.2}", pr.training_spend),
        ]);
    }
    println!("{}", t.to_markdown());
    println!("winner: {} | {}", report.arch, report.summary());
    println!(
        "exploration tax charged for dropped candidates: ${:.2}",
        report.cost.exploration
    );
    Ok(())
}
