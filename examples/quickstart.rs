//! Quickstart: label a small synthetic dataset with MCAL in ~10 seconds.
//!
//! ```bash
//! make artifacts          # once: AOT-compile the JAX/Pallas models
//! cargo run --release --offline --example quickstart
//! ```

use std::sync::Arc;

use mcal::annotation::{Ledger, Service, SimService, SimServiceConfig};
use mcal::coordinator::{run_mcal, LabelingDriver, RunParams};
use mcal::dataset::preset;
use mcal::model::ArchKind;
use mcal::runtime::{Engine, Manifest};

fn main() -> mcal::Result<()> {
    // 1. Runtime: PJRT CPU engine + the AOT artifact manifest.
    let engine = Engine::cpu()?;
    let manifest = Manifest::load("artifacts")?;

    // 2. A dataset to label: 10% subsample of the Fashion-MNIST analog.
    let p = preset("fashion-syn", 42)?;
    let mut ds = p.spec.scaled(0.1).generate()?;
    ds.name = "fashion-syn".into();
    println!("dataset: {} samples, {} classes", ds.len(), ds.num_classes);

    // 3. A labeling service (Amazon pricing: $0.04/label) and a ledger.
    let ledger = Arc::new(Ledger::new());
    let service = SimService::new(
        SimServiceConfig { service: Service::Amazon, ..Default::default() },
        ledger.clone(),
    );

    // 4. Run MCAL: ε = 5% error budget, margin-based acquisition.
    let report = run_mcal(
        &LabelingDriver::new(&engine, &manifest),
        &ds,
        &service,
        ledger,
        ArchKind::Res18,
        p.classes_tag,
        RunParams { seed: 42, ..Default::default() },
    )?;

    // 5. The labeled dataset is complete; look at the bill.
    println!("\n{}", report.summary());
    println!(
        "\n  human labels bought : {}  (${:.2})",
        report.cost.labels_purchased, report.cost.human_labeling
    );
    println!("  machine labels      : {}", report.s_size);
    println!("  retrains            : {}  (${:.2})", report.cost.retrains, report.cost.training);
    println!(
        "  vs human-only       : ${:.2}  ->  {:.0}% saved",
        report.human_only_cost,
        report.savings() * 100.0
    );
    println!(
        "  overall label error : {:.2}%  (budget {:.0}%)",
        report.overall_error * 100.0,
        report.epsilon * 100.0
    );
    Ok(())
}
