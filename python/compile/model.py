"""L2: MCAL classifier models in JAX, built on the L1 Pallas kernels.

The paper trains CNN18 / ResNet18 / ResNet50 (and EfficientNet-B0 for
ImageNet) on image pixels. Our substrate operates on 64-d feature vectors
(see docs/DESIGN.md §Substitutions) and uses MLP *analogs* that preserve the two
orderings MCAL's optimizer actually consumes: achievable accuracy
(res50 > res18 > cnn18) and training cost per sample (res50 > res18 > cnn18).

Every entry point works on a **flat f32 parameter vector** so the Rust L3
runtime can hold model state as a single device buffer per model:

- ``init(seed)``                      -> flat params
- ``train_step(p, v, x, y, lr)``      -> (p', v', loss)     SGD + momentum + wd
- ``predict_score(p, x)``             -> (logits, margin, entropy, maxprob, pred)
- ``features(p, x)``                  -> penultimate activations (for k-center)

All dense layers go through :func:`kernels.matmul.dense` (Pallas, custom
VJP), and scoring goes through :func:`kernels.uncertainty.score_logits`, so
the lowered HLO contains the L1 kernels on both the forward and backward hot
paths.
"""

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul, uncertainty

FEAT_DIM = 64
TRAIN_BS = 256
EVAL_BS = 512

MOMENTUM = 0.9
WEIGHT_DECAY = 5e-4


@dataclass(frozen=True)
class ArchConfig:
    """MLP analog of one of the paper's CNN architectures."""

    name: str
    hidden: int
    depth: int          # number of hidden->hidden blocks (beyond the stem)
    residual: bool

    def layer_shapes(self, classes: int) -> List[Tuple[str, Tuple[int, ...]]]:
        shapes: List[Tuple[str, Tuple[int, ...]]] = []
        shapes.append(("stem_w", (FEAT_DIM, self.hidden)))
        shapes.append(("stem_b", (self.hidden,)))
        for i in range(self.depth):
            shapes.append((f"blk{i}_w", (self.hidden, self.hidden)))
            shapes.append((f"blk{i}_b", (self.hidden,)))
        shapes.append(("head_w", (self.hidden, classes)))
        shapes.append(("head_b", (classes,)))
        return shapes

    def param_count(self, classes: int) -> int:
        total = 0
        for _, shp in self.layer_shapes(classes):
            n = 1
            for d in shp:
                n *= d
            total += n
        return total

    def flops_per_sample(self, classes: int) -> int:
        """Forward MACs×2; the rig cost model multiplies by 3 for fwd+bwd."""
        fl = 2 * FEAT_DIM * self.hidden
        fl += self.depth * 2 * self.hidden * self.hidden
        fl += 2 * self.hidden * classes
        return fl


# The paper's architecture menu (§5): analogs keyed by paper name.
ARCHS: Dict[str, ArchConfig] = {
    "cnn18": ArchConfig("cnn18", hidden=48, depth=2, residual=False),
    "res18": ArchConfig("res18", hidden=192, depth=4, residual=True),
    "res50": ArchConfig("res50", hidden=384, depth=8, residual=True),
    "effb0": ArchConfig("effb0", hidden=256, depth=6, residual=True),
}


def _offsets(arch: ArchConfig, classes: int):
    offs = []
    pos = 0
    for name, shp in arch.layer_shapes(classes):
        n = 1
        for d in shp:
            n *= d
        offs.append((name, shp, pos, n))
        pos += n
    return offs, pos


def unflatten(arch: ArchConfig, classes: int, flat):
    offs, total = _offsets(arch, classes)
    assert flat.shape == (total,), (flat.shape, total)
    params = {}
    for name, shp, pos, n in offs:
        params[name] = jax.lax.dynamic_slice(flat, (pos,), (n,)).reshape(shp)
    return params


def flatten_tree(arch: ArchConfig, classes: int, params) -> jnp.ndarray:
    offs, _ = _offsets(arch, classes)
    return jnp.concatenate([params[name].reshape(-1) for name, _, _, _ in offs])


def init(arch: ArchConfig, classes: int, key_data):
    """He-normal init from a uint32[2] key; returns the flat parameter vector."""
    key = jax.random.wrap_key_data(key_data.astype(jnp.uint32))
    offs, total = _offsets(arch, classes)
    parts = []
    for name, shp, _, n in offs:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            parts.append(jnp.zeros((n,), jnp.float32))
        else:
            fan_in = shp[0]
            scale = jnp.sqrt(2.0 / fan_in)
            w = jax.random.normal(sub, shp, jnp.float32) * scale
            # Residual branches get a damped init for stability at depth.
            if name.startswith("blk") and arch.residual:
                w = w * 0.7
            parts.append(w.reshape(-1))
    flat = jnp.concatenate(parts)
    assert flat.shape == (total,)
    return flat


def apply(arch: ArchConfig, classes: int, flat, x, *, return_features=False):
    """Forward pass over the Pallas dense kernel. x: (B, FEAT_DIM)."""
    p = unflatten(arch, classes, flat)
    h = matmul.dense(x, p["stem_w"], p["stem_b"], True)
    for i in range(arch.depth):
        out = matmul.dense(h, p[f"blk{i}_w"], p[f"blk{i}_b"], True)
        h = h + out if arch.residual else out
    if return_features:
        return h
    logits = matmul.dense(h, p["head_w"], p["head_b"], False)
    return logits


def loss_fn(arch: ArchConfig, classes: int, flat, x, y):
    logits = apply(arch, classes, flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    return jnp.mean(nll)


def train_step(arch: ArchConfig, classes: int, flat, vel, x, y, lr):
    """One SGD+momentum step on a fixed-size minibatch.

    Weight decay is applied to the whole flat vector (biases are a negligible
    fraction and this keeps the update a pure vector op).
    """
    loss, grad = jax.value_and_grad(
        lambda f: loss_fn(arch, classes, f, x, y)
    )(flat)
    grad = grad + WEIGHT_DECAY * flat
    vel = MOMENTUM * vel + grad
    flat = flat - lr * vel
    return flat, vel, loss


def predict_score(arch: ArchConfig, classes: int, flat, x):
    """Logits + the full uncertainty panel from the L1 scoring kernel."""
    logits = apply(arch, classes, flat, x)
    margin, entropy, maxprob, pred = uncertainty.score_logits(logits)
    return logits, margin, entropy, maxprob, pred


def features(arch: ArchConfig, classes: int, flat, x):
    return apply(arch, classes, flat, x, return_features=True)


# --------------------------------------------------------------------------
# State-vector entry points (what actually gets AOT-lowered).
#
# The PJRT build the `xla` crate binds returns multi-output executables as a
# single *tuple buffer* which cannot be fed back as an array input, so any
# value the Rust runtime must keep device-resident has to ride a
# single-array-output executable. We therefore pack (params, velocity) into
# one flat ``state`` vector of length 2P: ``train_chunk`` maps state->state'
# (single output, lax.scan over K minibatches), and all read-only entry
# points slice the params half out of state.
# --------------------------------------------------------------------------

# Minibatches per train_chunk call. One host->device transfer of
# (K, TRAIN_BS, FEAT_DIM) covers K optimizer steps.
CHUNK_STEPS = 8


def init_state(arch: ArchConfig, classes: int, key_data):
    """state[2P] = [he-init params | zero velocity]."""
    flat = init(arch, classes, key_data)
    return jnp.concatenate([flat, jnp.zeros_like(flat)])


def split_state(arch: ArchConfig, classes: int, state):
    p = arch.param_count(classes)
    return state[:p], state[p:]


def train_chunk(arch: ArchConfig, classes: int, state, xs, ys, lrs):
    """Run CHUNK_STEPS SGD steps; xs: (K, TRAIN_BS, FEAT_DIM), ys: (K, TRAIN_BS),
    lrs: (K,). Returns the updated state (single array output)."""
    flat, vel = split_state(arch, classes, state)

    def body(carry, batch):
        f, v = carry
        x, y, lr = batch
        f, v, _ = train_step(arch, classes, f, v, x, y, lr)
        return (f, v), ()

    (flat, vel), _ = jax.lax.scan(body, (flat, vel), (xs, ys, lrs))
    return jnp.concatenate([flat, vel])


def predict_score_s(arch: ArchConfig, classes: int, state, x):
    flat, _ = split_state(arch, classes, state)
    return predict_score(arch, classes, flat, x)


def features_s(arch: ArchConfig, classes: int, state, x):
    flat, _ = split_state(arch, classes, state)
    return features(arch, classes, flat, x)


def mean_loss_s(arch: ArchConfig, classes: int, state, x, y):
    """Mean CE over a fixed eval batch (single output; monitoring/tests)."""
    flat, _ = split_state(arch, classes, state)
    return loss_fn(arch, classes, flat, x, y)
