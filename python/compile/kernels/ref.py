"""Pure-jnp correctness oracles for every L1 Pallas kernel.

pytest (python/tests/) sweeps shapes/dtypes with hypothesis and asserts
``assert_allclose(kernel(...), ref(...))`` — the core L1 correctness signal.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    return jnp.matmul(x, w).astype(jnp.float32)


def dense_ref(x, w, b, relu: bool = True):
    y = jnp.matmul(x, w) + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(jnp.float32)


def softmax_ref(logits):
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    ez = jnp.exp(z)
    return ez / jnp.sum(ez, axis=-1, keepdims=True)


def score_logits_ref(logits):
    """(margin, entropy, maxprob, pred) — oracle for uncertainty.score_logits."""
    p = softmax_ref(logits)
    order = jnp.sort(p, axis=-1)
    p1 = order[:, -1]
    p2 = order[:, -2]
    pred = jnp.argmax(p, axis=-1).astype(jnp.int32)
    plogp = jnp.where(p > 0.0, p * jnp.log(p), 0.0)
    entropy = -jnp.sum(plogp, axis=-1)
    return p1 - p2, entropy, p1, pred


def kcenter_update_ref(feats, center, dists):
    diff = feats - center[None, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    return jnp.minimum(dists, d2)


def kcenter_block_update_ref(feats, centers, dists):
    """Fold of kcenter_update_ref over the block's rows."""
    for j in range(centers.shape[0]):
        dists = kcenter_update_ref(feats, centers[j], dists)
    return dists


def kcenter_pair_ref(dists):
    return jnp.stack([jnp.max(dists), jnp.argmax(dists).astype(jnp.float32)])
