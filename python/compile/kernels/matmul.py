"""Tiled dense-layer Pallas kernel with a Pallas backward pass.

``dense(x, w, b, activation)`` computes ``act(x @ w + b)`` through a blocked
Pallas matmul kernel and exposes a ``jax.custom_vjp`` so the L2 training graph
(autodiff through ``train_step``) also runs on the same kernel:

  forward :   y  = act(x @ w + b)          (one kernel launch)
  backward:   g  = dy * act'(y)            (elementwise, fused in kernel)
              dx = g @ w^T                 (same tiled kernel)
              dw = x^T @ g                 (same tiled kernel)
              db = sum_rows(g)

TPU mapping (docs/DESIGN.md §Hardware-adaptation): the grid is (M/bm, N/bn); each
grid step keeps an (bm, K) x-tile, a (K, bn) w-tile, and an (bm, bn) output
tile resident in VMEM and issues bm×bn×K MACs to the MXU. K (feature /
hidden width, ≤ 512 in our architectures) is kept whole so no K-loop /
accumulator revisit is needed; for K beyond VMEM one would add a third grid
axis with an accumulator in scratch. ``interpret=True`` lowers all of this to
plain HLO for the CPU PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block sizes. 128 matches the MXU systolic edge; the N tile is
# shrunk automatically for narrow layers (e.g. the C=10 logit layer).
BLOCK_M = 128
BLOCK_N = 128


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is ≤ preferred (falls back to dim)."""
    if dim <= preferred:
        return dim
    for cand in range(preferred, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile: whole-K contraction on the MXU."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(x, w, *, bm: int = BLOCK_M, bn: int = BLOCK_N):
    """Blocked ``x @ w`` via Pallas. x: (M, K), w: (K, N) -> (M, N) f32."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {w.shape}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def _dense_fwd_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _dense_raw(x, w, b, relu: bool):
    m, k = x.shape
    _, n = w.shape
    bm = _pick_block(m, BLOCK_M)
    bn = _pick_block(n, BLOCK_N)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_dense_fwd_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, relu: bool = True):
    """act(x @ w + b) with act = ReLU (relu=True) or identity."""
    return _dense_raw(x, w, b, relu)


def _dense_fwd(x, w, b, relu):
    y = _dense_raw(x, w, b, relu)
    # Save y rather than the pre-activation: the ReLU mask is y > 0.
    return y, (x, w, y)


def _dense_bwd(relu, res, dy):
    x, w, y = res
    if relu:
        dy = jnp.where(y > 0.0, dy, 0.0)
    # Both gradient matmuls ride the same tiled Pallas kernel.
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)


def vmem_bytes(m: int, k: int, n: int, bm: int = BLOCK_M, bn: int = BLOCK_N,
               bytes_per_el: int = 4) -> int:
    """Per-grid-step VMEM footprint estimate for the fwd kernel (DESIGN §Perf)."""
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    return bytes_per_el * (bm * k + k * bn + bn + bm * bn)
