"""k-center (core-set) min-distance update Pallas kernel.

The k-center-greedy selection of Sener & Savarese (the core-set M(.) variant
MCAL evaluates in Fig. 5/6/11) maintains, for every pool sample, the squared
L2 distance to its nearest already-chosen center in feature space. Each
round picks the farthest sample and relaxes all distances against the new
center:

    dists[i] = min(dists[i], ||feats[i] - center||^2)

That relaxation over the whole pool is the hot loop (|pool| × h per chosen
center) and is the kernel below. Grid over row-tiles of the feature matrix;
the feature width h (96–384) stays resident in lanes.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 256


def _pick_rows(m: int, preferred: int = ROW_BLOCK) -> int:
    if m <= preferred:
        return m
    for cand in range(preferred, 0, -1):
        if m % cand == 0:
            return cand
    return m


def _kcenter_kernel(feats_ref, center_ref, dists_ref, out_ref):
    f = feats_ref[...]              # (bm, h)
    c = center_ref[...][None, :]    # (1, h)
    diff = f - c
    d2 = jnp.sum(diff * diff, axis=-1)
    out_ref[...] = jnp.minimum(dists_ref[...], d2)


@jax.jit
def kcenter_update(feats, center, dists):
    """Relax min-squared-distances against a new center.

    feats: (M, h), center: (h,), dists: (M,) -> (M,) updated dists.
    """
    m, h = feats.shape
    bm = _pick_rows(m)
    grid = (m // bm,)
    return pl.pallas_call(
        _kcenter_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(feats, center, dists)
