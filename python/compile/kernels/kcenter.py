"""k-center (core-set) min-distance update Pallas kernel.

The k-center-greedy selection of Sener & Savarese (the core-set M(.) variant
MCAL evaluates in Fig. 5/6/11) maintains, for every pool sample, the squared
L2 distance to its nearest already-chosen center in feature space. Each
round picks the farthest sample and relaxes all distances against the new
center:

    dists[i] = min(dists[i], ||feats[i] - center||^2)

That relaxation over the whole pool is the hot loop (|pool| × h per chosen
center) and is the kernel below. Grid over row-tiles of the feature matrix;
the feature width h (96–384) stays resident in lanes.

Two launch granularities are exported:

- :func:`kcenter_update` — one center per launch (the original flat path,
  kept for the before/after benchmark sections);
- :func:`kcenter_block_update` — a *block* of ``CENTER_BLOCK`` centers per
  launch, folded inside the kernel, paired with :func:`kcenter_pair` (a
  max+argmax reduce) so the Rust driver reads back one ``(best_d, best_i)``
  pair per chunk instead of the full distance vector. ``min`` is
  idempotent, so short blocks are padded by *repeating* a real center —
  padding never perturbs a distance.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 256

# Centers folded per kcenter_block_update launch. Baked into the AOT
# artifact shapes and exported through the manifest (`kcenter_block`), so
# the Rust driver pads its center blocks to exactly this many rows.
CENTER_BLOCK = 16


def _pick_rows(m: int, preferred: int = ROW_BLOCK) -> int:
    if m <= preferred:
        return m
    for cand in range(preferred, 0, -1):
        if m % cand == 0:
            return cand
    return m


def _kcenter_kernel(feats_ref, center_ref, dists_ref, out_ref):
    f = feats_ref[...]              # (bm, h)
    c = center_ref[...][None, :]    # (1, h)
    diff = f - c
    d2 = jnp.sum(diff * diff, axis=-1)
    out_ref[...] = jnp.minimum(dists_ref[...], d2)


@jax.jit
def kcenter_update(feats, center, dists):
    """Relax min-squared-distances against a new center.

    feats: (M, h), center: (h,), dists: (M,) -> (M,) updated dists.
    """
    m, h = feats.shape
    bm = _pick_rows(m)
    grid = (m // bm,)
    return pl.pallas_call(
        _kcenter_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(feats, center, dists)


def _kcenter_block_kernel(feats_ref, centers_ref, dists_ref, out_ref):
    f = feats_ref[...]        # (bm, h)
    cs = centers_ref[...]     # (B, h) — whole block resident per tile
    d = dists_ref[...]        # (bm,)
    # B is a static shape: the loop unrolls at trace time into B fused
    # relaxations, one launch instead of B.
    for j in range(cs.shape[0]):
        diff = f - cs[j][None, :]
        d = jnp.minimum(d, jnp.sum(diff * diff, axis=-1))
    out_ref[...] = d


@jax.jit
def kcenter_block_update(feats, centers, dists):
    """Relax min-squared-distances against a block of centers in one launch.

    feats: (M, h), centers: (B, h), dists: (M,) -> (M,) updated dists.
    Equivalent to folding :func:`kcenter_update` over the block's rows;
    repeated rows are harmless (min is idempotent), which is how callers
    pad blocks shorter than B.
    """
    m, h = feats.shape
    b = centers.shape[0]
    bm = _pick_rows(m)
    grid = (m // bm,)
    return pl.pallas_call(
        _kcenter_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, h), lambda i: (i, 0)),
            pl.BlockSpec((b, h), lambda i: (0, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(feats, centers, dists)


@jax.jit
def kcenter_pair(dists):
    """Per-chunk (max distance, argmax index) as one f32[2] array.

    The only host readback of the blocked k-center driver: 2 floats per
    chunk per round instead of the full distance vector. Ties take the
    *first* (lowest-index) maximum — jnp.argmax's documented behavior —
    which the Rust host ref mirrors with a strict `>` scan. The index is
    exact in f32 (chunk rows ≪ 2^24). Single-array output on purpose: the
    PJRT build feeds back / reads only untupled results (see aot.py).
    """
    return jnp.stack(
        [jnp.max(dists), jnp.argmax(dists).astype(jnp.float32)]
    )
