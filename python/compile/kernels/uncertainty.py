"""Uncertainty-scoring Pallas kernel — the M(.) / L(.) metric hot-spot (§3.3).

Given a tile of logits (rows = samples, cols = classes) the kernel emits, per
row, every uncertainty statistic MCAL's sample-selection functions consume:

- ``margin``     : p(top1) − p(top2)   (Scheffer et al.; used for L(.) and
                   the default M(.))
- ``entropy``    : −Σ p log p          (max-entropy M(.), Dagan & Engelson)
- ``maxprob``    : p(top1)             (least-confidence M(.) = 1 − maxprob,
                   Culotta & McCallum)
- ``pred``       : argmax class        (the machine label itself)

TPU mapping: grid over row-tiles; the class dimension (10–1000) lives whole
in the lane dimension so the top-2 reduction is a pair of in-register
max/masked-max passes — the same trick a CUDA warp reduction would do, but
expressed as VPU reductions over the lane axis. Softmax is computed in a
numerically-stable shifted form.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 128


def _pick_rows(m: int, preferred: int = ROW_BLOCK) -> int:
    if m <= preferred:
        return m
    for cand in range(preferred, 0, -1):
        if m % cand == 0:
            return cand
    return m


def _score_kernel(logits_ref, margin_ref, entropy_ref, maxprob_ref, pred_ref):
    z = logits_ref[...]  # (bm, C)
    zmax = jnp.max(z, axis=-1, keepdims=True)
    ez = jnp.exp(z - zmax)
    denom = jnp.sum(ez, axis=-1, keepdims=True)
    p = ez / denom

    p1 = jnp.max(p, axis=-1)
    pred = jnp.argmax(p, axis=-1).astype(jnp.int32)
    # Masked second max: knock out the argmax column, take max again.
    cols = jax.lax.broadcasted_iota(jnp.int32, z.shape, dimension=1)
    masked = jnp.where(cols == pred[:, None], -jnp.inf, p)
    p2 = jnp.max(masked, axis=-1)
    # Entropy in a 0*log(0)-safe form.
    plogp = jnp.where(p > 0.0, p * jnp.log(p), 0.0)

    margin_ref[...] = p1 - p2
    entropy_ref[...] = -jnp.sum(plogp, axis=-1)
    maxprob_ref[...] = p1
    pred_ref[...] = pred


@jax.jit
def score_logits(logits):
    """Per-row uncertainty stats. logits: (M, C) -> (margin, entropy, maxprob, pred)."""
    m, c = logits.shape
    bm = _pick_rows(m)
    grid = (m // bm,)
    out_shapes = (
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.int32),
    )
    row_spec = pl.BlockSpec((bm,), lambda i: (i,))
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, c), lambda i: (i, 0))],
        out_specs=(row_spec, row_spec, row_spec, row_spec),
        out_shape=out_shapes,
        interpret=True,
    )(logits)
