"""L1 Pallas kernels for MCAL.

Every compute hot-spot of the MCAL pipeline is implemented as a Pallas
kernel so that the L2 jax entry points lower them into the same HLO module:

- :mod:`.matmul` — tiled dense layer (matmul + bias + optional ReLU) with a
  custom VJP whose backward pass reuses the same tiled kernel for the
  dgrad / wgrad matmuls. This is the training/inference hot loop.
- :mod:`.uncertainty` — per-row top-2 / entropy / max-prob scoring of logits;
  this is the `M(.)` / `L(.)` metric kernel of the paper (§3.3).
- :mod:`.kcenter` — blocked min-distance update for k-center (core-set)
  sample selection (Sener & Savarese baseline in Fig. 5/6/11).

All kernels run with ``interpret=True`` (see docs/DESIGN.md §Hardware-adaptation):
they lower to plain HLO executable on the CPU PJRT plugin; real-TPU tiling
is expressed through the BlockSpecs and documented VMEM/MXU estimates.
"""

from . import matmul, uncertainty, kcenter, ref  # noqa: F401
