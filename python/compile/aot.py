"""AOT: lower every L2 entry point to HLO *text* + write the manifest.

Run once at build time (``make artifacts``); the Rust L3 runtime loads the
text artifacts through ``HloModuleProto::from_text_file`` and never imports
Python again.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 (the version the published ``xla`` 0.1.6 crate binds)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Artifacts per model set (arch × classes). ``state`` packs (params, velocity)
into one 2P vector so the hot feedback loop (train_chunk) has a SINGLE array
output — this PJRT build returns multi-output executables as one tuple
buffer that cannot be fed back as an input, so anything device-resident must
ride a single-output executable (see rust/src/runtime/):

  init_{model}.hlo.txt     (key u32[2])                       -> state[2P]
  train_{model}.hlo.txt    (state, xs[K,256,64], ys[K,256]i32, lrs[K]) -> state'
  predict_{model}.hlo.txt  (state, x[512,64]) -> (logits, margin, entropy, maxprob, pred)
  feats_{model}.hlo.txt    (state, x[512,64])                 -> feats[512,H]
  loss_{model}.hlo.txt     (state, x[512,64], y[512]i32)      -> loss[]

plus, per distinct feature width, the k-center relax kernels — the flat
single-center one and the blocked variant (B = kernels.kcenter.CENTER_BLOCK
centers folded per launch, exported as the manifest global `kcenter_block`)
— and one width-independent pair reduce whose f32[2] output is the blocked
driver's only per-chunk readback:

  kcenter_h{H}.hlo.txt        (feats[512,H], center[H], dists[512])     -> dists'
  kcenter_block_h{H}.hlo.txt  (feats[512,H], centers[B,H], dists[512])  -> dists'
  kcenter_pair.hlo.txt        (dists[512]) -> [max_d, argmax_i as f32]

The manifest (artifacts/manifest.txt) is a line-oriented key/value format so
the Rust side needs no JSON/serde dependency.
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import kcenter

# (model_name, arch, classes) — every combination an experiment needs.
# C=10  : fashion-syn + cifar10-syn      (paper: Fashion-MNIST / CIFAR-10)
# C=100 : cifar100-syn                   (paper: CIFAR-100)
# C=300 : imagenet-syn                   (paper: ImageNet, scaled — docs/DESIGN.md §Substitutions)
MODEL_SETS = [
    ("cnn18_c10", "cnn18", 10),
    ("res18_c10", "res18", 10),
    ("res50_c10", "res50", 10),
    ("cnn18_c100", "cnn18", 100),
    ("res18_c100", "res18", 100),
    ("res50_c100", "res50", 100),
    ("effb0_c300", "effb0", 300),
]


def to_hlo_text(lowered, return_tuple: bool) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def lower_and_write(fn, example_args, path: str, *, return_tuple: bool) -> int:
    """return_tuple=False single-array-output artifacts are the ones whose
    outputs the Rust runtime feeds back device-side via execute_b."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered, return_tuple)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_model_set(out_dir: str, name: str, arch_name: str, classes: int):
    arch = model.ARCHS[arch_name]
    p = arch.param_count(classes)
    fd, tbs, ebs = model.FEAT_DIM, model.TRAIN_BS, model.EVAL_BS

    k = model.CHUNK_STEPS
    t0 = time.time()
    lower_and_write(
        lambda key: model.init_state(arch, classes, key),
        (spec((2,), jnp.uint32),),
        os.path.join(out_dir, f"init_{name}.hlo.txt"),
        return_tuple=False,
    )
    lower_and_write(
        lambda st, xs, ys, lrs: model.train_chunk(arch, classes, st, xs, ys, lrs),
        (spec((2 * p,)), spec((k, tbs, fd)), spec((k, tbs), jnp.int32), spec((k,))),
        os.path.join(out_dir, f"train_{name}.hlo.txt"),
        return_tuple=False,
    )
    lower_and_write(
        lambda st, x: model.predict_score_s(arch, classes, st, x),
        (spec((2 * p,)), spec((ebs, fd))),
        os.path.join(out_dir, f"predict_{name}.hlo.txt"),
        return_tuple=True,
    )
    lower_and_write(
        lambda st, x: model.features_s(arch, classes, st, x),
        (spec((2 * p,)), spec((ebs, fd))),
        os.path.join(out_dir, f"feats_{name}.hlo.txt"),
        return_tuple=False,
    )
    lower_and_write(
        lambda st, x, y: model.mean_loss_s(arch, classes, st, x, y),
        (spec((2 * p,)), spec((ebs, fd)), spec((ebs,), jnp.int32)),
        os.path.join(out_dir, f"loss_{name}.hlo.txt"),
        return_tuple=False,
    )
    dt = time.time() - t0
    print(f"  {name}: params={p} flops/sample={arch.flops_per_sample(classes)} ({dt:.1f}s)")
    return {
        "name": name,
        "arch": arch_name,
        "classes": classes,
        "hidden": arch.hidden,
        "depth": arch.depth,
        "residual": int(arch.residual),
        "params": p,
        "flops_per_sample": arch.flops_per_sample(classes),
    }


def build_kcenter(out_dir: str, hidden: int):
    lower_and_write(
        lambda f, c, d: kcenter.kcenter_update(f, c, d),
        (spec((model.EVAL_BS, hidden)), spec((hidden,)), spec((model.EVAL_BS,))),
        os.path.join(out_dir, f"kcenter_h{hidden}.hlo.txt"),
        return_tuple=False,
    )
    lower_and_write(
        lambda f, c, d: kcenter.kcenter_block_update(f, c, d),
        (
            spec((model.EVAL_BS, hidden)),
            spec((kcenter.CENTER_BLOCK, hidden)),
            spec((model.EVAL_BS,)),
        ),
        os.path.join(out_dir, f"kcenter_block_h{hidden}.hlo.txt"),
        return_tuple=False,
    )
    print(f"  kcenter_h{hidden} + kcenter_block_h{hidden}")


def build_kcenter_pair(out_dir: str):
    lower_and_write(
        lambda d: kcenter.kcenter_pair(d),
        (spec((model.EVAL_BS,)),),
        os.path.join(out_dir, "kcenter_pair.hlo.txt"),
        return_tuple=False,
    )
    print("  kcenter_pair")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--only", default=None,
        help="comma-separated model-set names (default: all)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    sets = [s for s in MODEL_SETS if only is None or s[0] in only]

    print(f"lowering {len(sets)} model sets -> {args.out}")
    rows = []
    for name, arch_name, classes in sets:
        rows.append(build_model_set(args.out, name, arch_name, classes))

    for hidden in sorted({model.ARCHS[a].hidden for _, a, _ in sets}):
        build_kcenter(args.out, hidden)
    build_kcenter_pair(args.out)

    manifest = os.path.join(args.out, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("version 1\n")
        f.write(f"feat_dim {model.FEAT_DIM}\n")
        f.write(f"train_bs {model.TRAIN_BS}\n")
        f.write(f"eval_bs {model.EVAL_BS}\n")
        f.write(f"momentum {model.MOMENTUM}\n")
        f.write(f"weight_decay {model.WEIGHT_DECAY}\n")
        f.write(f"chunk_steps {model.CHUNK_STEPS}\n")
        f.write(f"kcenter_block {kcenter.CENTER_BLOCK}\n")
        for r in rows:
            f.write(
                "model {name} arch {arch} classes {classes} hidden {hidden} "
                "depth {depth} residual {residual} params {params} "
                "flops_per_sample {flops_per_sample}\n".format(**r)
            )
    print(f"wrote {manifest}")


if __name__ == "__main__":
    main()
