"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

hypothesis sweeps shapes and value distributions; assert_allclose against
ref.py is the core L1 correctness signal (the same kernels lower into every
AOT artifact the Rust runtime executes).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

# hypothesis is not in every image's baked package set; skip (don't crash
# collection) where it is missing — the deterministic L1 checks in
# test_model.py / test_aot.py still run there.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import matmul, uncertainty, kcenter, ref  # noqa: E402

SET = settings(max_examples=25, deadline=None)


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


# ---------------------------------------------------------------- matmul

@SET
@given(
    m=st.sampled_from([1, 7, 32, 128, 256, 512]),
    k=st.sampled_from([3, 16, 64, 192]),
    n=st.sampled_from([5, 10, 96, 100, 384]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(
        matmul.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


@SET
@given(
    m=st.sampled_from([8, 64, 256]),
    k=st.sampled_from([16, 64]),
    n=st.sampled_from([10, 96, 100]),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(m, k, n, relu, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    np.testing.assert_allclose(
        matmul.dense(x, w, b, relu), ref.dense_ref(x, w, b, relu),
        rtol=1e-4, atol=1e-4,
    )


@SET
@given(
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_custom_vjp_matches_autodiff_of_ref(relu, seed):
    """Gradient through the Pallas kernel == gradient through the jnp oracle."""
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, 64, 32), rand(rng, 32, 48), rand(rng, 48)

    def lk(w, b, x):
        return jnp.sum(jnp.tanh(matmul.dense(x, w, b, relu)))

    def lr(w, b, x):
        return jnp.sum(jnp.tanh(ref.dense_ref(x, w, b, relu)))

    gk = jax.grad(lk, argnums=(0, 1, 2))(w, b, x)
    gr = jax.grad(lr, argnums=(0, 1, 2))(w, b, x)
    for a, e in zip(gk, gr):
        np.testing.assert_allclose(a, e, rtol=1e-3, atol=1e-3)


def test_dense_relu_mask_boundary():
    """Exactly-zero pre-activations must gate gradient like the oracle (0)."""
    x = jnp.ones((4, 4), jnp.float32)
    w = jnp.zeros((4, 4), jnp.float32)
    b = jnp.zeros((4,), jnp.float32)
    g = jax.grad(lambda w: jnp.sum(matmul.dense(x, w, b, True)))(w)
    np.testing.assert_allclose(g, jnp.zeros_like(g))


@SET
@given(m=st.sampled_from([17, 100, 250]), seed=st.integers(0, 2**31 - 1))
def test_matmul_non_divisible_rows(m, seed):
    """Block picker must handle row counts with awkward factorizations."""
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, 64), rand(rng, 64, 96)
    np.testing.assert_allclose(
        matmul.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


def test_vmem_estimate_within_budget():
    """All production layer shapes stay under a 16 MiB VMEM budget."""
    from compile import model
    for arch in model.ARCHS.values():
        for classes in (10, 100, 300):
            for _, shp in arch.layer_shapes(classes):
                if len(shp) != 2:
                    continue
                k, n = shp
                vb = matmul.vmem_bytes(model.TRAIN_BS, k, n)
                assert vb <= 16 * 1024 * 1024, (arch.name, shp, vb)


# ---------------------------------------------------------- uncertainty

@SET
@given(
    m=st.sampled_from([1, 13, 128, 512]),
    c=st.sampled_from([2, 10, 100, 300]),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_score_logits_matches_ref(m, c, scale, seed):
    rng = np.random.default_rng(seed)
    logits = rand(rng, m, c, scale=scale)
    got = uncertainty.score_logits(logits)
    want = ref.score_logits_ref(logits)
    for g, w_ in zip(got[:3], want[:3]):
        np.testing.assert_allclose(g, w_, rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(got[3], want[3])


def test_score_logits_extreme_values_stable():
    """Huge logits must not produce NaN/inf (stable shifted softmax)."""
    logits = jnp.asarray(
        [[1e4, -1e4, 0.0], [500.0, 499.0, -500.0], [0.0, 0.0, 0.0]], jnp.float32
    )
    margin, entropy, maxprob, pred = uncertainty.score_logits(logits)
    for v in (margin, entropy, maxprob):
        assert np.all(np.isfinite(np.asarray(v)))
    assert float(margin[0]) == pytest.approx(1.0, abs=1e-6)
    assert float(maxprob[2]) == pytest.approx(1.0 / 3.0, abs=1e-6)


def test_score_logits_margin_properties():
    rng = np.random.default_rng(7)
    logits = rand(rng, 256, 10, scale=3.0)
    margin, entropy, maxprob, pred = uncertainty.score_logits(logits)
    m_np = np.asarray(margin)
    assert np.all(m_np >= -1e-6) and np.all(m_np <= 1.0 + 1e-6)
    assert np.all(np.asarray(maxprob) >= 1.0 / 10 - 1e-6)
    assert np.all(np.asarray(entropy) <= np.log(10) + 1e-5)
    assert np.array_equal(np.asarray(pred), np.argmax(np.asarray(logits), axis=1))


# ------------------------------------------------------------- kcenter

@SET
@given(
    m=st.sampled_from([1, 64, 500, 512]),
    h=st.sampled_from([8, 96, 192, 384]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kcenter_update_matches_ref(m, h, seed):
    rng = np.random.default_rng(seed)
    f = rand(rng, m, h)
    c = rand(rng, h)
    d = jnp.abs(rand(rng, m, scale=50.0))
    np.testing.assert_allclose(
        kcenter.kcenter_update(f, c, d),
        ref.kcenter_update_ref(f, c, d),
        rtol=1e-4, atol=1e-4,
    )


def test_kcenter_update_monotone_nonincreasing():
    rng = np.random.default_rng(3)
    f = rand(rng, 128, 96)
    d = jnp.full((128,), 1e9, jnp.float32)
    for i in range(5):
        c = rand(rng, 96)
        d2 = kcenter.kcenter_update(f, c, d)
        assert np.all(np.asarray(d2) <= np.asarray(d) + 1e-6)
        d = d2


def test_kcenter_zero_distance_to_own_center():
    rng = np.random.default_rng(4)
    f = rand(rng, 32, 16)
    d = jnp.full((32,), 1e9, jnp.float32)
    d = kcenter.kcenter_update(f, f[7], d)
    assert float(d[7]) == pytest.approx(0.0, abs=1e-5)


@SET
@given(
    m=st.sampled_from([1, 64, 500, 512]),
    h=st.sampled_from([8, 96, 192, 384]),
    b=st.sampled_from([1, 3, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kcenter_block_matches_ref(m, h, b, seed):
    rng = np.random.default_rng(seed)
    f = rand(rng, m, h)
    cs = rand(rng, b, h)
    d = jnp.abs(rand(rng, m, scale=50.0))
    np.testing.assert_allclose(
        kcenter.kcenter_block_update(f, cs, d),
        ref.kcenter_block_update_ref(f, cs, d),
        rtol=1e-4, atol=1e-4,
    )


@SET
@given(seed=st.integers(0, 2**31 - 1))
def test_kcenter_block_padding_by_repetition_is_identity(seed):
    """The driver pads short blocks by repeating a center: min is
    idempotent, so the padded block must relax exactly like the short one."""
    rng = np.random.default_rng(seed)
    f = rand(rng, 128, 96)
    cs = rand(rng, 3, 96)
    padded = jnp.concatenate(
        [cs, jnp.broadcast_to(cs[-1], (kcenter.CENTER_BLOCK - 3, 96))]
    )
    d = jnp.abs(rand(rng, 128, scale=50.0))
    np.testing.assert_array_equal(
        kcenter.kcenter_block_update(f, padded, d),
        kcenter.kcenter_block_update(f, cs, d),
    )


@SET
@given(m=st.sampled_from([1, 100, 512]), seed=st.integers(0, 2**31 - 1))
def test_kcenter_pair_matches_ref(m, seed):
    rng = np.random.default_rng(seed)
    d = jnp.abs(rand(rng, m, scale=50.0))
    got = np.asarray(kcenter.kcenter_pair(d))
    want = np.asarray(ref.kcenter_pair_ref(d))
    np.testing.assert_array_equal(got, want)
    i = int(got[1])
    assert float(got[0]) == float(d[i])


def test_kcenter_pair_ties_take_first_index():
    d = jnp.asarray([1.0, 7.0, 7.0, 0.0], jnp.float32)
    pair = np.asarray(kcenter.kcenter_pair(d))
    assert pair[0] == 7.0 and pair[1] == 1.0
