"""L2 model tests: shapes, flat-param layout round-trip, training dynamics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


ARCH_CASES = [("cnn18", 10), ("res18", 10), ("res50", 100), ("effb0", 300)]


@pytest.mark.parametrize("arch_name,classes", ARCH_CASES)
def test_param_count_matches_layout(arch_name, classes):
    arch = model.ARCHS[arch_name]
    total = sum(int(np.prod(s)) for _, s in arch.layer_shapes(classes))
    assert arch.param_count(classes) == total


@pytest.mark.parametrize("arch_name,classes", ARCH_CASES)
def test_init_shape_and_determinism(arch_name, classes):
    arch = model.ARCHS[arch_name]
    key = jnp.asarray([1, 2], jnp.uint32)
    p1 = model.init(arch, classes, key)
    p2 = model.init(arch, classes, key)
    assert p1.shape == (arch.param_count(classes),)
    np.testing.assert_array_equal(p1, p2)
    p3 = model.init(arch, classes, jnp.asarray([3, 4], jnp.uint32))
    assert not np.array_equal(np.asarray(p1), np.asarray(p3))


def test_flatten_unflatten_roundtrip():
    arch = model.ARCHS["res18"]
    key = jnp.asarray([5, 6], jnp.uint32)
    flat = model.init(arch, 10, key)
    tree = model.unflatten(arch, 10, flat)
    flat2 = model.flatten_tree(arch, 10, tree)
    np.testing.assert_array_equal(flat, flat2)


@pytest.mark.parametrize("arch_name,classes", [("cnn18", 10), ("res18", 100)])
def test_apply_shapes(arch_name, classes, rng):
    arch = model.ARCHS[arch_name]
    flat = model.init(arch, classes, jnp.asarray([0, 1], jnp.uint32))
    x = jnp.asarray(rng.normal(size=(model.EVAL_BS, model.FEAT_DIM)), jnp.float32)
    logits = model.apply(arch, classes, flat, x)
    assert logits.shape == (model.EVAL_BS, classes)
    feats = model.features(arch, classes, flat, x)
    assert feats.shape == (model.EVAL_BS, arch.hidden)


def test_predict_score_shapes(rng):
    arch = model.ARCHS["cnn18"]
    flat = model.init(arch, 10, jnp.asarray([0, 1], jnp.uint32))
    x = jnp.asarray(rng.normal(size=(model.EVAL_BS, model.FEAT_DIM)), jnp.float32)
    logits, margin, entropy, maxprob, pred = model.predict_score(arch, 10, flat, x)
    assert logits.shape == (model.EVAL_BS, 10)
    for v in (margin, entropy, maxprob):
        assert v.shape == (model.EVAL_BS,)
        assert np.all(np.isfinite(np.asarray(v)))
    assert pred.dtype == jnp.int32


def test_train_step_reduces_loss_on_separable_data(rng):
    """A few steps on linearly separable blobs must cut the loss."""
    arch = model.ARCHS["cnn18"]
    classes = 10
    flat = model.init(arch, classes, jnp.asarray([7, 8], jnp.uint32))
    vel = jnp.zeros_like(flat)

    centers = rng.normal(size=(classes, model.FEAT_DIM)) * 4.0
    y = rng.integers(0, classes, size=model.TRAIN_BS)
    x = centers[y] + rng.normal(size=(model.TRAIN_BS, model.FEAT_DIM)) * 0.3
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.int32)
    lr = jnp.asarray(0.01, jnp.float32)

    step = jax.jit(lambda f, v: model.train_step(arch, classes, f, v, x, y, lr))
    _, _, loss0 = step(flat, vel)
    for _ in range(30):
        flat, vel, loss = step(flat, vel)
    assert float(loss) < 0.5 * float(loss0), (float(loss0), float(loss))


def test_train_step_loss_matches_manual_ce(rng):
    arch = model.ARCHS["cnn18"]
    flat = model.init(arch, 10, jnp.asarray([1, 1], jnp.uint32))
    x = jnp.asarray(rng.normal(size=(model.TRAIN_BS, model.FEAT_DIM)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, model.TRAIN_BS), jnp.int32)
    loss = model.loss_fn(arch, 10, flat, x, y)
    logits = np.asarray(model.apply(arch, 10, flat, x))
    z = logits - logits.max(axis=1, keepdims=True)
    logp = z - np.log(np.exp(z).sum(axis=1, keepdims=True))
    want = -logp[np.arange(len(y)), np.asarray(y)].mean()
    assert float(loss) == pytest.approx(want, rel=1e-5)


def test_residual_vs_plain_forward_differ(rng):
    """Sanity: the residual flag changes the computation."""
    a_res = model.ArchConfig("t", hidden=32, depth=2, residual=True)
    a_pln = model.ArchConfig("t", hidden=32, depth=2, residual=False)
    flat = jnp.asarray(rng.normal(size=(a_res.param_count(10),)), jnp.float32) * 0.1
    x = jnp.asarray(rng.normal(size=(8, model.FEAT_DIM)), jnp.float32)
    lr_ = model.apply(a_res, 10, flat, x)
    lp = model.apply(a_pln, 10, flat, x)
    assert not np.allclose(np.asarray(lr_), np.asarray(lp))


def test_init_state_layout():
    arch = model.ARCHS["cnn18"]
    st = model.init_state(arch, 10, jnp.asarray([2, 3], jnp.uint32))
    p = arch.param_count(10)
    assert st.shape == (2 * p,)
    np.testing.assert_array_equal(np.asarray(st[p:]), np.zeros(p, np.float32))
    flat, vel = model.split_state(arch, 10, st)
    np.testing.assert_array_equal(flat, st[:p])
    np.testing.assert_array_equal(vel, st[p:])


def test_train_chunk_equals_unrolled_steps(rng):
    """scan-based train_chunk must match CHUNK_STEPS manual train_step calls."""
    arch = model.ARCHS["cnn18"]
    classes = 10
    st = model.init_state(arch, classes, jnp.asarray([9, 9], jnp.uint32))
    k, bs = model.CHUNK_STEPS, model.TRAIN_BS
    xs = jnp.asarray(rng.normal(size=(k, bs, model.FEAT_DIM)), jnp.float32)
    ys = jnp.asarray(rng.integers(0, classes, (k, bs)), jnp.int32)
    lrs = jnp.asarray(rng.uniform(0.001, 0.01, k), jnp.float32)

    got = model.train_chunk(arch, classes, st, xs, ys, lrs)

    flat, vel = model.split_state(arch, classes, st)
    for i in range(k):
        flat, vel, _ = model.train_step(arch, classes, flat, vel, xs[i], ys[i], lrs[i])
    want = jnp.concatenate([flat, vel])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_train_chunk_reduces_eval_loss(rng):
    arch = model.ARCHS["cnn18"]
    classes = 10
    st = model.init_state(arch, classes, jnp.asarray([7, 8], jnp.uint32))
    centers = rng.normal(size=(classes, model.FEAT_DIM)) * 3.0
    k, bs = model.CHUNK_STEPS, model.TRAIN_BS
    y = rng.integers(0, classes, size=(k, bs))
    xs = jnp.asarray(centers[y] + rng.normal(size=(k, bs, model.FEAT_DIM)) * 0.3, jnp.float32)
    ys = jnp.asarray(y, jnp.int32)
    lrs = jnp.full((k,), 0.01, jnp.float32)
    step = jax.jit(lambda s: model.train_chunk(arch, classes, s, xs, ys, lrs))

    ye = rng.integers(0, classes, size=model.EVAL_BS)
    xe = jnp.asarray(
        centers[ye] + rng.normal(size=(model.EVAL_BS, model.FEAT_DIM)) * 0.3, jnp.float32
    )
    ye = jnp.asarray(ye, jnp.int32)
    l0 = float(model.mean_loss_s(arch, classes, st, xe, ye))
    for _ in range(6):
        st = step(st)
    l1 = float(model.mean_loss_s(arch, classes, st, xe, ye))
    assert l1 < 0.5 * l0, (l0, l1)


def test_flops_ordering_matches_paper():
    """Cost ordering res50 > effb0-ish > res18 > cnn18 (DESIGN §Substitutions)."""
    f = {n: a.flops_per_sample(10) for n, a in model.ARCHS.items()}
    assert f["res50"] > f["res18"] > f["cnn18"]
    assert f["effb0"] > f["res18"]
