"""AOT artifact tests: manifest consistency + HLO text sanity.

These run against the artifacts/ directory if it exists (built by
``make artifacts``); they are skipped on a clean tree so `pytest` stays
runnable before the first build.
"""

import os

import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.txt")),
    reason="artifacts not built (run `make artifacts`)",
)


def read_manifest():
    out = {"models": {}}
    with open(os.path.join(ART, "manifest.txt")) as f:
        for line in f:
            parts = line.split()
            if not parts:
                continue
            if parts[0] == "model":
                kv = dict(zip(parts[2::2], parts[3::2]))
                out["models"][parts[1]] = kv
            else:
                out[parts[0]] = parts[1]
    return out


def test_manifest_globals():
    m = read_manifest()
    assert int(m["feat_dim"]) == model.FEAT_DIM
    assert int(m["train_bs"]) == model.TRAIN_BS
    assert int(m["eval_bs"]) == model.EVAL_BS


def test_manifest_covers_all_model_sets():
    m = read_manifest()
    for name, arch_name, classes in aot.MODEL_SETS:
        assert name in m["models"], name
        kv = m["models"][name]
        arch = model.ARCHS[arch_name]
        assert int(kv["classes"]) == classes
        assert int(kv["params"]) == arch.param_count(classes)
        assert int(kv["hidden"]) == arch.hidden
        assert int(kv["flops_per_sample"]) == arch.flops_per_sample(classes)


def test_all_artifact_files_exist_and_are_hlo_text():
    m = read_manifest()
    kinds = ["init", "train", "predict", "feats", "loss"]
    for name in m["models"]:
        for kind in kinds:
            path = os.path.join(ART, f"{kind}_{name}.hlo.txt")
            assert os.path.exists(path), path
            head = open(path).read(200)
            assert "HloModule" in head, path
    hiddens = {int(kv["hidden"]) for kv in m["models"].values()}
    for h in hiddens:
        for stem in (f"kcenter_h{h}", f"kcenter_block_h{h}"):
            path = os.path.join(ART, f"{stem}.hlo.txt")
            assert os.path.exists(path), path
    assert os.path.exists(os.path.join(ART, "kcenter_pair.hlo.txt"))


def test_manifest_kcenter_block_matches_kernel_constant():
    from compile.kernels import kcenter

    m = read_manifest()
    assert int(m["kcenter_block"]) == kcenter.CENTER_BLOCK


def test_kcenter_block_artifact_shapes():
    """The blocked relax must stay single-array-output (its dists feed back
    device-side) and carry the (EVAL_BS, h) / (CENTER_BLOCK, h) inputs the
    Rust driver pads to."""
    from compile.kernels import kcenter

    m = read_manifest()
    h = min(int(kv["hidden"]) for kv in m["models"].values())
    text = open(os.path.join(ART, f"kcenter_block_h{h}.hlo.txt")).read()
    assert f"f32[{kcenter.CENTER_BLOCK},{h}]" in text
    root_lines = [l for l in text.splitlines() if "ROOT" in l and "ENTRY" not in l]
    assert any(f"f32[{model.EVAL_BS}]" in l for l in root_lines)
    pair = open(os.path.join(ART, "kcenter_pair.hlo.txt")).read()
    pair_roots = [l for l in pair.splitlines() if "ROOT" in l and "ENTRY" not in l]
    assert any("f32[2]" in l for l in pair_roots)


def test_train_artifact_mentions_expected_shapes():
    m = read_manifest()
    name, kv = next(iter(m["models"].items()))
    p = int(kv["params"])
    k = model.CHUNK_STEPS
    text = open(os.path.join(ART, f"train_{name}.hlo.txt")).read()
    assert f"f32[{2 * p}]" in text                                   # state
    assert f"f32[{k},{model.TRAIN_BS},{model.FEAT_DIM}]" in text     # xs
    assert f"s32[{k},{model.TRAIN_BS}]" in text                      # ys
    # Single-array output: the entry root must be state-shaped, not a tuple.
    root_lines = [l for l in text.splitlines() if "ROOT" in l and "ENTRY" not in l]
    assert any(f"f32[{2 * p}]" in l for l in root_lines)


def test_manifest_chunk_steps():
    m = read_manifest()
    assert int(m["chunk_steps"]) == model.CHUNK_STEPS
